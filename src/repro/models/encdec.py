"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

``input_specs`` supplies precomputed log-mel *frame embeddings* (B, F, D) —
the conv frontend is out of scope per the assignment.  Encoder: bidirectional
attention over frames with sinusoidal positions.  Decoder: causal self-attn +
cross-attn + MLP, learned positions.  Decode shapes exercise the decoder
(self-attn KV cache of seq_len + fixed cross-attn KV).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (
    ParamDef, attn_apply, attn_schema, compute_kv, mlp_apply, mlp_schema,
    rmsnorm, sinusoidal_positions, stack_schema,
)
from repro.models.transformer import (
    Q_CHUNK, BLOCKED_MIN_SEQ, cross_entropy, scan_or_unroll,
)
from repro.parallel.embed import embed_lookup
from repro.parallel.sharding import constraint

MAX_DEC_POS = 32768


def encdec_schema(cfg) -> Dict[str, Any]:
    D, V = cfg.d_model, cfg.padded_vocab
    enc_block = {
        "ln1": ParamDef((D,), (None,), "zeros"),
        "attn": attn_schema(cfg),
        "ln2": ParamDef((D,), (None,), "zeros"),
        "mlp": mlp_schema(cfg),
    }
    dec_block = {
        "ln1": ParamDef((D,), (None,), "zeros"),
        "attn": attn_schema(cfg),
        "lnx": ParamDef((D,), (None,), "zeros"),
        "xattn": attn_schema(cfg),
        "ln2": ParamDef((D,), (None,), "zeros"),
        "mlp": mlp_schema(cfg),
    }
    return {
        "emb": ParamDef((V, D), ("vocab", None), scale=0.02),
        "pos_emb": ParamDef((MAX_DEC_POS, D), (None, "embed"), scale=0.02),
        "head": ParamDef((D, V), ("embed", "vocab")),
        "enc_blocks": stack_schema(enc_block, cfg.n_enc_layers),
        "dec_blocks": stack_schema(dec_block, cfg.n_layers),
        "enc_norm": ParamDef((D,), (None,), "zeros"),
        "final_norm": ParamDef((D,), (None,), "zeros"),
    }


def encode(params, cfg, frames, mesh=None):
    """frames: (B, F, D) stub embeddings -> encoder states (B, F, D)."""
    B, F, D = frames.shape
    x = frames + sinusoidal_positions(F, D).astype(frames.dtype)[None]
    if mesh is not None:
        x = constraint(x, ("batch", None, None), mesh)

    def body(x, bp):
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
        a, _ = attn_apply(bp["attn"], h, cfg, causal=False)
        x = x + a
        h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        return x + mlp_apply(bp["mlp"], h), None

    x, _ = scan_or_unroll(cfg, body, x, params["enc_blocks"],
                          cfg.n_enc_layers)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_hidden(params, cfg, tokens, enc, mesh, collect_cache=False):
    B, S = tokens.shape
    x = embed_lookup(params["emb"], tokens, mesh)
    x = x + params["pos_emb"][:S][None].astype(x.dtype)
    if mesh is not None:
        x = constraint(x, ("batch", None, "act_embed"), mesh)
    q_chunk = cfg.q_chunk or (Q_CHUNK if S >= BLOCKED_MIN_SEQ else 0)
    positions = jnp.arange(S)

    def body(x, bp):
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
        a, (k, v) = attn_apply(bp["attn"], h, cfg, positions=positions,
                               q_chunk=q_chunk)
        x = x + a
        h = rmsnorm(x, bp["lnx"], cfg.norm_eps)
        xk, xv = compute_kv(bp["xattn"], enc, cfg)
        a, _ = attn_apply(bp["xattn"], h, cfg, kv=(xk, xv), cross=True)
        x = x + a
        h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(bp["mlp"], h)
        out = (k, v, xk, xv) if collect_cache else None
        return x, out

    x, caches = scan_or_unroll(cfg, body, x, params["dec_blocks"],
                               cfg.n_layers)
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), caches


def _dec_logits(params, cfg, x, mesh):
    lg = jnp.einsum("bsd,dv->bsv", x, params["head"])
    if mesh is not None:
        lg = constraint(lg, ("batch", None, "vocab"), mesh)
    return lg


def encdec_loss(params, cfg, batch, mesh=None):
    enc = encode(params, cfg, batch["frames"], mesh)
    x, _ = _dec_hidden(params, cfg, batch["tokens"], enc, mesh)
    logits = _dec_logits(params, cfg, x, mesh)
    ce = cross_entropy(logits, batch["labels"], cfg.vocab)
    return ce, {"ce": ce, "aux": 0.0}


def encdec_prefill(params, cfg, batch, mesh=None,
                   max_len: Optional[int] = None):
    enc = encode(params, cfg, batch["frames"], mesh)
    tokens = batch["tokens"]
    B, S = tokens.shape
    T = max_len or S
    x, caches = _dec_hidden(params, cfg, tokens, enc, mesh,
                            collect_cache=True)
    k, v, xk, xv = caches
    if T > S:
        padw = ((0, 0), (0, 0), (0, T - S), (0, 0), (0, 0))
        k, v = jnp.pad(k, padw), jnp.pad(v, padw)
    logits = _dec_logits(params, cfg, x[:, -1:], mesh)[:, 0]
    cache = {"k": k, "v": v, "xk": xk, "xv": xv,
             "cur": jnp.asarray(S, jnp.int32)}
    return logits, cache


def encdec_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    K, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    F = cfg.enc_frames
    return {
        "k": jnp.zeros((L, batch, max_len, K, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, K, hd), dtype),
        "xk": jnp.zeros((L, batch, F, K, hd), dtype),
        "xv": jnp.zeros((L, batch, F, K, hd), dtype),
        "cur": jnp.zeros((), jnp.int32),
    }


def encdec_decode_step(params, cfg, cache, tokens, mesh=None):
    B = tokens.shape[0]
    cur = cache["cur"]
    x = embed_lookup(params["emb"], tokens, mesh)
    x = x + jnp.take(params["pos_emb"], cur[None], axis=0)[None].astype(x.dtype)
    T = cache["k"].shape[2]
    k_pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    k_valid = k_pos <= cur
    qpos = jnp.broadcast_to(cur[None, None], (B, 1))

    def body(x, inp):
        bp, ck, cv, xk, xv = inp
        h = rmsnorm(x, bp["ln1"], cfg.norm_eps)
        knew, vnew = compute_kv(bp["attn"], h, cfg, positions=qpos)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, knew.astype(ck.dtype),
                                                 cur, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vnew.astype(cv.dtype),
                                                 cur, axis=1)
        a, _ = attn_apply(bp["attn"], h, cfg, positions=qpos, kv=(ck, cv),
                          k_pos=k_pos, k_valid=k_valid)
        x = x + a
        h = rmsnorm(x, bp["lnx"], cfg.norm_eps)
        a, _ = attn_apply(bp["xattn"], h, cfg, kv=(xk, xv), cross=True)
        x = x + a
        h = rmsnorm(x, bp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(bp["mlp"], h)
        return x, (ck, cv)

    x, (nk, nv) = scan_or_unroll(
        cfg, body, x, (params["dec_blocks"], cache["k"], cache["v"],
                       cache["xk"], cache["xv"]), cfg.n_layers)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _dec_logits(params, cfg, x, mesh)[:, 0]
    new_cache = dict(cache, k=nk, v=nv, cur=cur + 1)
    return logits, new_cache
