"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM.

mLSTM is a matrix-memory linear-attention cell with exponential input gates
and sigmoid forget gates.  We implement the *exactly stabilized* chunkwise
form: within a chunk the pairwise weights are computed with a running
``cummax`` stabilizer; across chunks the matrix state is carried re-scaled by
``exp(-m)``.  Decode is the standard O(1) recurrent step.  sLSTM is inherently
sequential (per the paper) and is implemented as a ``lax.scan`` over time.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamDef

MCHUNK = 128


# --------------------------------------------------------------------------
# Schemas
# --------------------------------------------------------------------------
def mlstm_schema(cfg) -> Dict[str, ParamDef]:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    Q = H * hd
    return {
        "wq": ParamDef((D, Q), ("embed", "heads")),
        "wk": ParamDef((D, Q), ("embed", "heads")),
        "wv": ParamDef((D, Q), ("embed", "heads")),
        "wi": ParamDef((D, H), ("embed", None), scale=0.02),
        "wf": ParamDef((D, H), ("embed", None), scale=0.02),
        "bf": ParamDef((H,), (None,), "ones"),   # bias>0 -> remember by default
        "wo": ParamDef((Q, D), ("heads", "embed")),
        "ogate": ParamDef((D, Q), ("embed", "heads"), scale=0.02),
    }


def slstm_schema(cfg) -> Dict[str, ParamDef]:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    Q = H * hd
    return {
        "wz": ParamDef((D, Q), ("embed", "heads")),
        "wi": ParamDef((D, Q), ("embed", "heads"), scale=0.02),
        "wf": ParamDef((D, Q), ("embed", "heads"), scale=0.02),
        "wog": ParamDef((D, Q), ("embed", "heads"), scale=0.02),
        "rz": ParamDef((H, hd, hd), ("heads", None, None), scale=0.02),
        "ri": ParamDef((H, hd, hd), ("heads", None, None), scale=0.02),
        "rf": ParamDef((H, hd, hd), ("heads", None, None), scale=0.02),
        "ro": ParamDef((H, hd, hd), ("heads", None, None), scale=0.02),
        "bf": ParamDef((Q,), ("heads",), "ones"),
        "wo": ParamDef((Q, D), ("heads", "embed")),
    }


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def _mlstm_qkv(p, x, cfg):
    B, L, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bld,dq->blq", x, p["wq"]).reshape(B, L, H, hd)
    k = jnp.einsum("bld,dq->blq", x, p["wk"]).reshape(B, L, H, hd) / np.sqrt(hd)
    v = jnp.einsum("bld,dq->blq", x, p["wv"]).reshape(B, L, H, hd)
    li = jnp.einsum("bld,dh->blh", x, p["wi"]).astype(jnp.float32)     # log input gate
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bld,dh->blh", x, p["wf"]).astype(jnp.float32)
        + p["bf"].astype(jnp.float32))                                  # log forget
    og = jax.nn.sigmoid(jnp.einsum("bld,dq->blq", x, p["ogate"])
                        .astype(jnp.float32)).reshape(B, L, H, hd)
    return q, k, v, li, lf, og


def mlstm_init_state(cfg, batch: int):
    H, hd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def mlstm_apply(p, x, cfg, state=None) -> Tuple[jnp.ndarray, dict]:
    """Chunkwise-parallel mLSTM.  x: (B,L,D) -> (B,L,D), final state."""
    B, L, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q, k, v, li, lf, og = _mlstm_qkv(p, x, cfg)
    if state is None:
        state = mlstm_init_state(cfg, B)

    Cn = MCHUNK
    Lp = ((L + Cn - 1) // Cn) * Cn
    if Lp != L:
        padl = Lp - L
        q = jnp.pad(q, ((0, 0), (0, padl), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, padl), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, padl), (0, 0), (0, 0)))
        li = jnp.pad(li, ((0, 0), (0, padl), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, padl), (0, 0)))
    nC = Lp // Cn

    def reshape_c(t):  # (B,Lp,...) -> (nC,B,Cn,...)
        return jnp.moveaxis(t.reshape(B, nC, Cn, *t.shape[2:]), 1, 0)

    qc, kc, vc = reshape_c(q.astype(jnp.float32)), reshape_c(k.astype(jnp.float32)), reshape_c(v.astype(jnp.float32))
    lic, lfc = reshape_c(li), reshape_c(lf)

    def chunk_step(carry, inp):
        Cmat, nvec, m_in = carry                  # scaled by exp(-m_in)
        qq, kk, vv, lii, lff = inp                # (B,Cn,H,*)
        LF = jnp.cumsum(lff, axis=1)              # (B,Cn,H) inclusive
        a = lii - LF                              # (B,Cn,H)
        mloc = jax.lax.cummax(a, axis=1)          # (B,Cn,H)
        mt = jnp.maximum(m_in[:, None, :], mloc)  # (B,Cn,H)

        # intra-chunk pairwise weights
        w_log = a[:, None, :, :] - mt[:, :, None, :]       # (B,t,s,H)
        tri = jnp.tril(jnp.ones((Cn, Cn), bool))
        w = jnp.exp(jnp.where(tri[None, :, :, None], w_log, -jnp.inf))
        qk = jnp.einsum("bthd,bshd->btsh", qq, kk)
        y_intra = jnp.einsum("btsh,bshd->bthd", qk * w, vv)
        n_intra = jnp.einsum("btsh,bshd->bthd", w, kk)

        # incoming-state contribution
        w_in = jnp.exp(m_in[:, None, :] - mt)              # (B,Cn,H)
        y_in = jnp.einsum("bthd,bhde->bthe", qq, Cmat) * w_in[..., None]
        n_in = jnp.einsum("bthd,bhd->bth", qq, nvec) * w_in
        n_dot = jnp.einsum("bthd,bthd->bth", qq, n_intra) + n_in
        denom = jnp.maximum(jnp.abs(n_dot), 1.0)[..., None]
        yt = (y_intra + y_in) / denom                      # (B,Cn,H,hd)

        # carry update (rescaled to m_out)
        F_tot = LF[:, -1, :]                               # (B,H)
        a_max = mloc[:, -1, :]
        m_out = F_tot + jnp.maximum(m_in, a_max)
        s_in = jnp.exp(m_in + F_tot - m_out)               # <=1
        wS = jnp.exp(a + F_tot[:, None, :] - m_out[:, None, :])  # (B,Cn,H)
        C_new = Cmat * s_in[:, :, None, None] + \
            jnp.einsum("bsh,bshd,bshe->bhde", wS, kk, vv)
        n_new = nvec * s_in[:, :, None] + jnp.einsum("bsh,bshd->bhd", wS, kk)
        return (C_new, n_new, m_out), yt

    carry0 = (state["C"], state["n"], state["m"])
    if getattr(cfg, "scan_layers", True):
        (Cf, nf, mf), ys = jax.lax.scan(chunk_step, carry0,
                                        (qc, kc, vc, lic, lfc))
    else:  # cost-probe mode: unrolled chunks
        carry, ys_l = carry0, []
        for i in range(nC):
            carry, y_i = chunk_step(carry, (qc[i], kc[i], vc[i],
                                            lic[i], lfc[i]))
            ys_l.append(y_i)
        (Cf, nf, mf), ys = carry, jnp.stack(ys_l)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Lp, H, hd)[:, :L]
    y = (y * og[:, :L]).reshape(B, L, H * hd).astype(x.dtype)
    out = jnp.einsum("blq,qd->bld", y, p["wo"])
    return out, {"C": Cf, "n": nf, "m": mf}


def mlstm_decode_step(p, x, state, cfg) -> Tuple[jnp.ndarray, dict]:
    """x: (B,1,D) exact recurrent step."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    q, k, v, li, lf, og = _mlstm_qkv(p, x, cfg)
    q, k, v = (t.astype(jnp.float32)[:, 0] for t in (q, k, v))   # (B,H,hd)
    li, lf = li[:, 0], lf[:, 0]                                  # (B,H)
    m_new = jnp.maximum(lf + state["m"], li)
    fw = jnp.exp(lf + state["m"] - m_new)
    iw = jnp.exp(li - m_new)
    C = state["C"] * fw[:, :, None, None] + \
        iw[:, :, None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n = state["n"] * fw[:, :, None] + iw[:, :, None] * k
    n_dot = jnp.einsum("bhd,bhd->bh", q, n)
    denom = jnp.maximum(jnp.abs(n_dot), 1.0)[..., None]
    y = jnp.einsum("bhd,bhde->bhe", q, C) / denom                # (B,H,hd)
    y = (y * og[:, 0]).reshape(B, 1, H * hd).astype(x.dtype)
    return jnp.einsum("blq,qd->bld", y, p["wo"]), \
        {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------
# sLSTM (sequential scan; not parallelizable, as the paper notes)
# --------------------------------------------------------------------------
def slstm_init_state(cfg, batch: int):
    H, hd = cfg.n_heads, cfg.head_dim
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, H, hd), -1e30, jnp.float32)}


def _slstm_step(p, cfg, state, gates):
    """gates: precomputed input projections (B,H,hd,4): z,i,f,o."""
    H, hd = cfg.n_heads, cfg.head_dim
    h = state["h"]                                       # (B,H,hd)
    rz = jnp.einsum("bhd,hde->bhe", h, p["rz"].astype(jnp.float32))
    ri = jnp.einsum("bhd,hde->bhe", h, p["ri"].astype(jnp.float32))
    rf = jnp.einsum("bhd,hde->bhe", h, p["rf"].astype(jnp.float32))
    ro = jnp.einsum("bhd,hde->bhe", h, p["ro"].astype(jnp.float32))
    zt = jnp.tanh(gates[..., 0] + rz)
    it = gates[..., 1] + ri                              # log-space
    ft = gates[..., 2] + rf
    ot = jax.nn.sigmoid(gates[..., 3] + ro)
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + state["m"], it)
    fw = jnp.exp(lf + state["m"] - m_new)
    iw = jnp.exp(it - m_new)
    c = fw * state["c"] + iw * zt
    n = fw * state["n"] + iw
    hnew = ot * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": hnew, "m": m_new}, hnew


def slstm_apply(p, x, cfg, state=None) -> Tuple[jnp.ndarray, dict]:
    B, L, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    if state is None:
        state = slstm_init_state(cfg, B)
    gz = jnp.einsum("bld,dq->blq", x, p["wz"])
    gi = jnp.einsum("bld,dq->blq", x, p["wi"])
    gf = jnp.einsum("bld,dq->blq", x, p["wf"]) + p["bf"]
    go = jnp.einsum("bld,dq->blq", x, p["wog"])
    g = jnp.stack([gz, gi, gf, go], axis=-1).astype(jnp.float32)
    g = g.reshape(B, L, H, hd, 4)

    def step(st, gt):
        return _slstm_step(p, cfg, st, gt)

    stf, hs = jax.lax.scan(step, state, jnp.moveaxis(g, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, L, H * hd).astype(x.dtype)
    return jnp.einsum("blq,qd->bld", y, p["wo"]), stf


def slstm_decode_step(p, x, state, cfg) -> Tuple[jnp.ndarray, dict]:
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim
    gz = jnp.einsum("bld,dq->blq", x, p["wz"])
    gi = jnp.einsum("bld,dq->blq", x, p["wi"])
    gf = jnp.einsum("bld,dq->blq", x, p["wf"]) + p["bf"]
    go = jnp.einsum("bld,dq->blq", x, p["wog"])
    g = jnp.stack([gz, gi, gf, go], -1).astype(jnp.float32).reshape(B, H, hd, 4)
    stf, h = _slstm_step(p, cfg, state, g)
    y = h.reshape(B, 1, H * hd).astype(x.dtype)
    return jnp.einsum("blq,qd->bld", y, p["wo"]), stf
