"""Top-k MoE with hierarchical (group-local) sort-based dispatch.

Two memory/communication hazards shape this design (measured in the dry-run,
see EXPERIMENTS.md §Perf):

1. the classic one-hot dispatch einsum is O(T·E·C) — hundreds of GB at the
   assigned global batches;
2. a *global* sort-based dispatch keeps gather/scatter indices global, and
   the backward scatter-add materializes replicated (T, D) f32 temps under
   GSPMD (+17 GB/device on qwen3-235B).

So tokens are first reshaped into G dispatch groups aligned with the data
axis (G = pod·data); argsort/bincount/gather/scatter are then *group-local*
(vmapped over G), which GSPMD shards cleanly along the group dim — no
cross-shard index traffic, backward stays shard-local.  Per-group capacity
C_loc = ceil(k·T_loc/E · cf) (local drops, MaxText-style) under the
``capacity`` routing mode; ``cfg.moe_routing = "dropless"`` sets
C_loc = T_loc instead (top_k indices are distinct per token, so no
expert can ever receive more), so no assignment can ever be dropped and the
layer is a pure per-token function — the serving plane runs dropless so
chunked prefill and batched decode reproduce the sequential reference
token-for-token (capacity mode stays the training default).  The expert FFN
is a grouped matmul (``kernels.moe_gmm`` on TPU; einsum fallback here) with
experts sharded over 'model' (EP) when divisible — granite's 40 experts fall
back to sharding expert d_ff (adaptive rule).

The gather/scatter access pattern is exactly the paper's RAO SCATTER/GATHER
CircusTent patterns — fine-grained irregular updates, the access class
Cohet's coherent fabric accelerates (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamDef


def moe_schema(cfg) -> Dict[str, ParamDef]:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    if cfg.infer_weight_layout:
        # serving layout: shard d_ff over 'data' instead of FSDP on d_model
        # -> the decode path reads expert weights gather-free (§Perf it.10)
        return {
            "router": ParamDef((D, E), (None, "experts"), scale=0.02),
            "wg": ParamDef((E, D, F), ("experts", None, "expert_ffn_d")),
            "wu": ParamDef((E, D, F), ("experts", None, "expert_ffn_d")),
            "wd": ParamDef((E, F, D), ("experts", "expert_ffn_d", None)),
        }
    return {
        "router": ParamDef((D, E), ("embed", "experts"), scale=0.02),
        "wg": ParamDef((E, D, F), ("experts", "embed", "expert_ffn")),
        "wu": ParamDef((E, D, F), ("experts", "embed", "expert_ffn")),
        "wd": ParamDef((E, F, D), ("experts", "expert_ffn", "embed")),
    }


def _capacity(cfg, n_tokens: int) -> int:
    """Per-group per-expert capacity.

    ``dropless``: C = Tl — top_k indices are distinct per token, so at
    most Tl of a group's assignments can land on any one expert and
    rank-in-expert tops out at Tl - 1 < C; ``slot < C`` always holds and
    routing is a pure per-token function (no drop can depend on
    co-resident tokens).

    ``capacity``: C = ceil(k*Tl/E * cf) with a top_k floor, clamped to
    Tl last — at most Tl tokens can ever rank into one expert, so any
    C > Tl is pure waste (the floor applied after the clamp used to
    yield C > Tl whenever top_k > Tl, e.g. tiny decode batches).
    """
    if cfg.moe_routing == "dropless":
        return n_tokens
    c = int(np.ceil(cfg.top_k * n_tokens / cfg.n_experts *
                    cfg.capacity_factor))
    return min(max(cfg.top_k, c), n_tokens)


def _n_groups(cfg, T: int, mesh) -> int:
    if mesh is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        g *= mesh.shape.get(ax, 1)
    return g if T % g == 0 else 1


def moe_apply(p, x, cfg, return_aux: bool = False, mesh=None,
              n_groups: int = 0):
    """x: (B, S, D) -> (B, S, D) [, aux losses dict].

    ``cfg.moe_routing == "dropless"`` makes the layer a pure per-token
    function (capacity can never bind): the output for token t is exactly
    sum_k gate_k * FFN_{e_k}(x_t), invariant to token order, group count,
    chunk splits and pad rows.  ``n_groups`` overrides the mesh-derived
    dispatch group count (tests; must divide B*S).
    """
    from repro.parallel.sharding import constraint

    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    G = n_groups or _n_groups(cfg, T, mesh)
    assert T % G == 0, (T, G)
    Tl = T // G
    C = _capacity(cfg, Tl)

    infer = cfg.infer_weight_layout

    def shard(t, logical):
        if infer:
            # serving layout: expert buffers replicated over 'data' (tiny at
            # decode batch sizes); weights keep their gather-free sharding
            logical = tuple(("experts" if n == "experts" else
                             "expert_ffn_d" if n == "expert_ffn" else None)
                            for n in logical)
        return constraint(t, logical, mesh) if mesh is not None else t

    xf = x.reshape(G, Tl, D)
    if mesh is not None:
        xf = constraint(xf, ("batch", None, None) if infer
                        else ("batch", None, "act_embed"), mesh)

    logits = jnp.einsum("gtd,de->gte", xf, p["router"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Tl,E) f32
    gates, eidx = jax.lax.top_k(probs, K)                      # (G,Tl,K)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    # ---- group-local sorted dispatch ----
    flat_e = eidx.reshape(G, Tl * K)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (G,TlK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)

    def _counts(fe):
        return jnp.zeros((E,), jnp.int32).at[fe].add(1)
    counts = jax.vmap(_counts)(flat_e)                         # (G,E)
    offsets = jnp.cumsum(counts, axis=-1) - counts             # (G,E)
    off_sorted = jnp.take_along_axis(offsets, sorted_e, axis=-1)
    slot = jnp.arange(Tl * K)[None] - off_sorted               # rank in expert
    keep = slot < C
    src_tok = order // K                                       # (G,TlK)
    dest = sorted_e * C + slot                                 # (G,TlK)

    def _table(dest_g, keep_g, src_g):
        return jnp.full((E * C,), Tl, jnp.int32).at[
            jnp.where(keep_g, dest_g, E * C)].set(
                src_g.astype(jnp.int32), mode="drop")
    table = jax.vmap(_table)(dest, keep, src_tok)              # (G,E*C)

    x_pad = jnp.concatenate([xf, jnp.zeros((G, 1, D), xf.dtype)], 1)
    xe = jnp.take_along_axis(
        x_pad, table[:, :, None].astype(jnp.int32), axis=1)    # (G,E*C,D)
    xe = shard(xe.reshape(G, E, C, D),
               ("batch", "experts", None, "act_embed"))

    # ---- grouped FFN (einsum fallback of kernels.moe_gmm) ----
    g_ = jnp.einsum("gecd,edf->gecf", xe, p["wg"])
    u_ = jnp.einsum("gecd,edf->gecf", xe, p["wu"])
    h = jax.nn.silu(g_.astype(jnp.float32)).astype(x.dtype) * u_
    h = shard(h, ("batch", "experts", None, "expert_ffn"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wd"])
    ye = shard(ye, ("batch", "experts", None, "act_embed"))
    ye = ye.reshape(G, E * C, D)

    # ---- combine: group-local scatter-add with gates ----
    gate_flat = jnp.take_along_axis(gates.reshape(G, Tl * K), order, axis=-1)

    def _gate_rows(dest_g, keep_g, gf):
        return jnp.zeros((E * C,), jnp.float32).at[
            jnp.where(keep_g, dest_g, E * C)].set(gf, mode="drop")
    gate_rows = jax.vmap(_gate_rows)(dest, keep, gate_flat)    # (G,E*C)

    def _combine(ye_g, tok_g, gr_g):
        contrib = ye_g * gr_g[:, None].astype(ye_g.dtype)
        return jnp.zeros((Tl + 1, D), ye_g.dtype).at[tok_g].add(
            contrib, mode="drop")[:Tl]
    y = jax.vmap(_combine)(ye, table, gate_rows)               # (G,Tl,D)
    y = shard(y, ("batch", None, "act_embed"))

    out = y.reshape(B, S, D)
    if not return_aux:
        return out
    me = probs.mean((0, 1))                                    # (E,)
    ce = (counts.sum(0) / jnp.maximum(1, T * K)).astype(jnp.float32)
    aux = {"load_balance": E * jnp.sum(me * ce),
           "router_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)}
    return out, aux
