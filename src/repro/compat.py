"""Single home for version-gated jax imports.

jax's public surface moved between 0.4.x and 0.5+ (most visibly
``jax.sharding.AxisType`` and the ``axis_types=`` kwarg on
``jax.make_mesh``).  Every module in this repo that needs a symbol whose
location or existence depends on the jax version imports it from here, so
the next jax bump is a one-file change.

Supported: jax >= 0.4.30 (tested on 0.4.37) and jax >= 0.5.
"""
from __future__ import annotations

import jax

# Stable across all supported versions — re-exported so callers never
# import from jax.sharding directly.
# repro-lint: disable=R8 -- re-export surface: parallel/*, core.rao, launch.dryrun import these from here
from jax.sharding import Mesh, NamedSharding, PartitionSpec  # noqa: F401


def _version_tuple(v: str):
    parts = []
    for tok in v.split(".")[:3]:
        digits = "".join(ch for ch in tok if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION = _version_tuple(jax.__version__)

try:  # jax >= 0.5: meshes carry explicit per-axis types
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    HAS_AXIS_TYPE = True
except ImportError:  # jax < 0.5: every axis is implicitly "auto"
    class AxisType:  # minimal stand-in so annotations/defaults still work
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"
    HAS_AXIS_TYPE = False


try:  # jax >= 0.5: shard_map is a public top-level API
    _shard_map = jax.shard_map  # type: ignore[attr-defined]
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

# the replication-check kwarg was renamed check_rep -> check_vma
_CHECK_KW = ("check_vma"
             if "check_vma" in _inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """Version-portable ``shard_map``: top-level on jax >= 0.5, experimental
    before; translates ``check_vma`` to the older ``check_rep`` spelling."""
    kwargs = {}
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def axis_size(axis_name):
    """``jax.lax.axis_size`` (jax >= 0.6); statically-folded psum before."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axes, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates jax without ``axis_types``.

    On jax >= 0.5 the requested (or all-Auto default) axis types are passed
    through; on jax < 0.5 they are dropped — which is behavior-preserving,
    since pre-0.5 meshes are implicitly fully automatic.
    """
    shape = tuple(shape)
    axes = tuple(axes)
    if not hasattr(jax, "make_mesh"):  # jax < 0.4.35
        from jax.experimental import mesh_utils
        devs = mesh_utils.create_device_mesh(shape, devices=devices)
        return Mesh(devs, axes)
    if HAS_AXIS_TYPE:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axes)
        return jax.make_mesh(shape, axes, axis_types=tuple(axis_types),
                             devices=devices)
    return jax.make_mesh(shape, axes, devices=devices)
