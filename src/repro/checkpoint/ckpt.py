"""Sharded, async, atomic checkpointing with elastic reshard-on-restore.

Layout: <dir>/step_<N>/{manifest.json, arr_<i>.npy...} written to a tmp dir
and atomically renamed (a crashed save never corrupts the latest).  Restore
maps arrays back onto the *current* mesh's shardings — restoring onto a
different (pod, data, model) factorization works (elastic scaling).
An async writer thread keeps saves off the training step path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(ckpt_dir: str, state: Any, step: int) -> str:
    """Synchronous atomic save."""
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f".tmp_step_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(state)
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef),
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "shapes": [list(np.asarray(l).shape) for l in leaves],
            "time": time.time()}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        # custom dtypes (bf16, f8) round-trip as raw bytes + manifest dtype
        np.save(tmp / f"arr_{i}.npy",
                np.frombuffer(arr.tobytes(), np.uint8))
    (tmp / "manifest.json").write_text(json.dumps(meta))
    final = d / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)          # atomic publish
    # prune older checkpoints (keep last 3)
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-3]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)
    return str(final)


class AsyncCheckpointer:
    """Off-thread saver; at most one pending save (latest wins)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._pending: Optional[Tuple[Any, int]] = None
        self._lock = threading.Lock()
        self._event = threading.Event()
        self._stop = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        self.saved_steps = []

    def submit(self, state: Any, step: int):
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        with self._lock:
            self._pending = (host_state, step)
        self._event.set()

    def _worker(self):
        while not self._stop:
            self._event.wait(timeout=0.2)
            with self._lock:
                job, self._pending = self._pending, None
                self._event.clear()
            if job is not None:
                state, step = job
                save(self.ckpt_dir, state, step)
                self.saved_steps.append(step)

    def wait_idle(self, timeout: float = 30.0):
        t0 = time.time()
        while time.time() - t0 < timeout:
            with self._lock:
                if self._pending is None:
                    return
            time.sleep(0.02)

    def close(self):
        self.wait_idle()
        self._stop = True
        self._event.set()
        self._thread.join(timeout=5)


def all_steps(ckpt_dir: str):
    d = Path(ckpt_dir)
    if not d.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))


def restore(ckpt_dir: str, step: int, like: Any, mesh=None,
            shardings=None) -> Any:
    """Restore `step` into the structure of `like`.  With `shardings`
    (pytree of NamedSharding, possibly for a DIFFERENT mesh than the one
    saved from), arrays are placed sharded — elastic reshard."""
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves), "structure mismatch"
    out = []
    sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                 else [None] * len(leaves))
    for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
        raw = np.load(d / f"arr_{i}.npy")
        src_dtype = _np_dtype(manifest["dtypes"][i])
        arr = raw.view(src_dtype).reshape(manifest["shapes"][i])
        target_dtype = np.asarray(leaf).dtype if hasattr(leaf, "dtype") \
            else arr.dtype
        if arr.dtype != target_dtype:
            arr = arr.astype(target_dtype)
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir: str, like: Any, mesh=None, shardings=None):
    steps = all_steps(ckpt_dir)
    if not steps:
        return None
    step = steps[-1]
    return restore(ckpt_dir, step, like, mesh, shardings), step
