# Cohet reproduction — developer entry points.
#
# `make test` is the tier-1 verify command (ROADMAP.md).
# `make bench-fast` runs the SimCXL DES-vs-batch sweep benchmark and
# refreshes BENCH_simcxl_sweep.json (the perf-trajectory record).
# `make bench-serve` runs the serving-engine benchmark and refreshes
# BENCH_serve.json (arrival patterns + continuous-vs-serial throughput).
# `make bench-decode` runs the paged-vs-dense decode benchmark and
# refreshes BENCH_decode.json (decode tok/s + admission cost grid).
# `make bench-check` re-runs the fast serve/decode benches into a scratch
# dir and fails on >30% throughput/TTFT regression vs the committed
# BENCH_*.json baselines (tools/bench_check.py).
# `make docs-check` fails if docs/ drift from the module tree.
# `make lint` runs repro-lint (tools/lint.py) over src/, benchmarks/ and
# launch entry points; fails on any unsuppressed finding (R1-R9).
# `make trace-audit` runs the jaxpr-level trace-contract auditor
# (tools/trace_audit.py): real engine builds vs the committed
# tools/trace_manifest.json graph set; fails on any J1-J5 finding.
# Both lint and trace-audit cache passing verdicts in .ci-cache/ keyed
# on a source digest, so reruns on an unchanged tree are instant.

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)
BENCH_FRESH ?= .bench-fresh

.PHONY: test test-collect bench-fast bench bench-des bench-serve \
	bench-serve-fast bench-decode bench-decode-fast bench-check docs-check \
	lint trace-audit

lint:
	$(PY) tools/lint.py src benchmarks --cache

trace-audit:
	$(PY) tools/trace_audit.py --cache

test:
	$(PY) -m pytest -x -q

test-collect:
	$(PY) -m pytest --collect-only -q

bench-fast:
	$(PY) benchmarks/sweep_bench.py --fast --out BENCH_simcxl_sweep.json

bench:
	$(PY) benchmarks/run.py

bench-des:
	$(PY) benchmarks/run.py --des

bench-serve:
	$(PY) benchmarks/serve_bench.py --out BENCH_serve.json

bench-serve-fast:
	$(PY) benchmarks/serve_bench.py --fast --out BENCH_serve.json

bench-decode:
	$(PY) benchmarks/decode_bench.py --out BENCH_decode.json

bench-decode-fast:
	$(PY) benchmarks/decode_bench.py --fast --out BENCH_decode.json

bench-check:
	mkdir -p $(BENCH_FRESH)
	$(PY) benchmarks/serve_bench.py --fast --out $(BENCH_FRESH)/BENCH_serve.json
	$(PY) benchmarks/decode_bench.py --fast --out $(BENCH_FRESH)/BENCH_decode.json
	$(PY) tools/bench_check.py --fresh $(BENCH_FRESH) --committed .

docs-check:
	$(PY) tools/docs_check.py
