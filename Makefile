# Cohet reproduction — developer entry points.
#
# `make test` is the tier-1 verify command (ROADMAP.md).
# `make bench-fast` runs the SimCXL DES-vs-batch sweep benchmark and
# refreshes BENCH_simcxl_sweep.json (the perf-trajectory record).

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-collect bench-fast bench

test:
	$(PY) -m pytest -x -q

test-collect:
	$(PY) -m pytest --collect-only -q

bench-fast:
	$(PY) benchmarks/sweep_bench.py --fast --out BENCH_simcxl_sweep.json

bench:
	$(PY) benchmarks/run.py

bench-des:
	$(PY) benchmarks/run.py --des
