"""Quickstart: train a small LM, checkpoint it, then serve it — the whole
framework loop in one script.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax

from repro.configs import get_config, reduced
from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def main():
    print("== 1. training (reduced mistral-nemo, synthetic data) ==")
    hist = train_mod.main(["--arch", "mistral-nemo-12b", "--steps", "40",
                           "--batch", "4", "--seq", "64", "--log-every", "10",
                           "--lr", "5e-3", "--ckpt-dir", "/tmp/quickstart_ckpt"])
    assert hist[-1]["loss"] < hist[0]["loss"]

    print("== 2. serving (batched requests over the RPC wire codec) ==")
    serve_mod.main(["--arch", "mistral-nemo-12b", "--requests", "4",
                    "--slots", "2", "--prompt-len", "8", "--max-new", "4"])

    print("== 3. SimCXL calibration snapshot ==")
    from repro.simcxl.calibration import calibrate
    r = calibrate(fast=True)
    print(f"SimCXL MAPE vs paper testbed: {r['mape']*100:.2f}% "
          f"(target <= 3%) -> {'PASS' if r['pass'] else 'FAIL'}")


if __name__ == "__main__":
    main()
