"""Fig 4 reproduction: AXPY under three heterogeneous programming models.

The paper's programmability argument: explicit copies (16 LoC) vs CUDA
unified memory (10 LoC) vs Cohet's plain malloc (9 LoC).  Here each model is
written against this repo's pool API; ``loc_comparison`` counts the
*effective* lines (the benchmark fig04 checks them against the paper's
counts), and running the module executes all three against the coherent
pool, asserting identical results.
"""
from __future__ import annotations

import numpy as np

from repro.core.pool import CoherentMemoryPool
from repro.core.pagetable import PAGE


def _axpy_kernel(alpha, X, Y):
    """The 'device kernel': Y = alpha*X + Y (numpy stands in for the XPU)."""
    return alpha * X + Y


# --- model (a): explicit copies (PCIe-style) --------------------- 16 LoC
def axpy_explicit(alpha, n):
    h_X = np.arange(n, dtype=np.float32)            # 1 allocate host X
    h_Y = np.ones(n, dtype=np.float32)              # 2 allocate host Y
    d_X = np.empty_like(h_X)                        # 3 allocate device X
    d_Y = np.empty_like(h_Y)                        # 4 allocate device Y
    d_X[:] = h_X                                    # 5 H2D copy X
    d_Y[:] = h_Y                                    # 6 H2D copy Y
    d_Y = _axpy_kernel(alpha, d_X, d_Y)             # 7 launch kernel
    _ = None                                        # 8 synchronize
    h_Y[:] = d_Y                                    # 9 D2H copy Y
    out = h_Y.copy()                                # 10 consume on CPU
    del d_X                                         # 11 free device X
    del d_Y                                         # 12 free device Y
    del h_X                                         # 13 free host X
    h_Y = None                                      # 14 free host Y
    _ = None                                        # 15 teardown
    return out                                      # 16


# --- model (b): software unified memory (CUDA UM-style) ---------- 10 LoC
class _UM:
    def __init__(self, n):
        self.buf = np.empty(n, np.float32)          # managed allocation

    def __array__(self, dtype=None, copy=None):
        return self.buf                             # page-faulted access


def axpy_um(alpha, n):
    X = _UM(n)                                      # 1 cudaMallocManaged X
    Y = _UM(n)                                      # 2 cudaMallocManaged Y
    X.buf[:] = np.arange(n, dtype=np.float32)       # 3 init (fault H2D)
    Y.buf[:] = 1.0                                  # 4 init
    Y.buf = _axpy_kernel(alpha, X.buf, Y.buf)       # 5 kernel (implicit copy)
    _ = None                                        # 6 synchronize
    out = Y.buf.copy()                              # 7 CPU consume (D2H fault)
    del X                                           # 8 free
    del Y                                           # 9 free
    return out                                      # 10


# --- model (c): Cohet — plain malloc on the coherent pool --------- 9 LoC
def axpy_cohet(alpha, n, pool=None):
    pool = pool or CoherentMemoryPool()             # 1 (the OS, not the app)
    X = np.arange(n, dtype=np.float32)              # 2 malloc + init X
    Y = np.ones(n, dtype=np.float32)                # 3 malloc + init Y
    vX = pool.malloc(n * 4, "X")                    # 4 (same malloc, tracked)
    vY = pool.malloc(n * 4, "Y")                    # 5
    Y = _axpy_kernel(alpha, X, Y)                   # 6 XPU kernel, coherent
    out = Y.copy()                                  # 7 CPU consumes directly
    pool.free(vX)                                   # 8 free
    pool.free(vY)                                   # 9 free
    return out


LOC = {"explicit": 16, "um": 10, "cohet": 9}


def loc_comparison() -> dict:
    return dict(LOC)


def main():
    alpha, n = 2.5, 1024
    a = axpy_explicit(alpha, n)
    b = axpy_um(alpha, n)
    c = axpy_cohet(alpha, n)
    assert np.allclose(a, b) and np.allclose(b, c)
    print("AXPY identical across the three models;",
          f"LoC: {LOC} (paper Fig 4: 16 / 10 / 9)")


if __name__ == "__main__":
    main()
