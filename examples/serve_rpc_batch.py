"""End-to-end serving driver (deliverable b): serve a small model with
BATCHED requests through the Cohet RPC front-end, reporting per-phase stats
and the SimCXL-estimated NIC offload gain for this workload's profile.

    PYTHONPATH=src python examples/serve_rpc_batch.py --requests 16
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import rpc as wire
from repro.models.model import build_model
from repro.runtime.server import BatchServer, encode_request
from repro.simcxl import FPGA_400MHZ
from repro.simcxl.nic import (
    RpcBench, cxlnic_deserialize_ns, rpcnic_deserialize_ns)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg)
    server = BatchServer(model, batch_slots=args.slots,
                         max_len=args.prompt_len + args.max_new + 2,
                         key=jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    wires = []
    for rid in range(args.requests):
        prompt = rng.randint(1, cfg.vocab - 1, size=args.prompt_len).tolist()
        wires.append(encode_request(rid, prompt, args.max_new))

    # profile the wire traffic -> SimCXL NIC offload estimate
    total_bytes = sum(len(w) for w in wires)
    prof = RpcBench("serve", n_fields=3, field_bytes=total_bytes //
                    (3 * len(wires)), nesting=1, n_msgs=len(wires))
    base = rpcnic_deserialize_ns(FPGA_400MHZ, prof)
    cxl = cxlnic_deserialize_ns(FPGA_400MHZ, prof)

    t0 = time.time()
    for w in wires:
        server.submit_wire(w)
    out = server.run_until_drained()
    dt = time.time() - t0

    done = sorted(wire.decode(b, {1: "int", 2: "bytes"})[1] for b in out)
    print(f"completed {len(out)}/{args.requests} requests in {dt:.2f}s; "
          f"stats={server.stats}")
    print(f"wire traffic: {total_bytes} B over {len(wires)} msgs; "
          f"SimCXL deser offload estimate: PCIe-NIC {base/1e3:.1f}us vs "
          f"CXL-NIC {cxl/1e3:.1f}us ({base/cxl:.2f}x)")
    assert done == list(range(args.requests))


if __name__ == "__main__":
    main()
