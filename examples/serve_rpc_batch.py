"""End-to-end serving driver: serve a small model under a trace-driven
request load through the Cohet RPC front-end, reporting latency percentiles,
scheduler stats, and the SimCXL-projected NIC offload gain for the run's
actual wire traffic.

    PYTHONPATH=src python examples/serve_rpc_batch.py --requests 16
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import rpc as wire
from repro.models.model import build_model
from repro.runtime.loadgen import make_trace, run_closed_loop
from repro.runtime.server import AsyncBatchServer, encode_request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--pattern", default="poisson",
                    choices=("poisson", "bursty", "all-at-once"))
    ap.add_argument("--rate", type=float, default=30.0)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    if cfg.family == "moe":
        # serving default: dropless routing — chunk-invariant prefill,
        # deterministic decode (launch.serve does the same)
        cfg = cfg.replace(moe_routing="dropless")
    model = build_model(cfg)
    server = AsyncBatchServer(model, batch_slots=args.slots,
                              max_len=args.prompt_len + args.max_new + 2,
                              key=jax.random.PRNGKey(0))

    rng = np.random.RandomState(0)
    wires = [encode_request(
        rid, rng.randint(1, cfg.vocab - 1, size=args.prompt_len).tolist(),
        args.max_new) for rid in range(args.requests)]
    trace = make_trace(args.pattern, args.requests, rate_rps=args.rate,
                       burst=args.slots, seed=0)

    # wire bytes go straight in: submit_wire does the ingress accounting
    out, metrics = run_closed_loop(server, wires, trace)

    done = sorted(wire.decode(b, {1: "int", 2: "bytes"})[1] for b in out)
    print(f"completed {metrics.completed}/{args.requests} requests in "
          f"{metrics.makespan_s:.2f}s; stats={server.stats}")
    print(f"load metrics: p50 {metrics.to_dict()['latency_p50_ms']}ms, "
          f"p99 {metrics.to_dict()['latency_p99_ms']}ms, "
          f"{metrics.to_dict()['tokens_per_s']} tok/s, "
          f"slot util {server.slot_utilization:.2f}")
    total_bytes = sum(len(w) for w in wires)
    nic = server.nic_report()
    print(f"wire traffic: {total_bytes} B over {len(wires)} msgs; "
          f"SimCXL NIC projection (deser+ser+tickets): "
          f"PCIe {nic['total']['pcie_us']:.1f}us vs "
          f"CXL {nic['total']['cxl_us']:.1f}us "
          f"({nic['total']['speedup_x']}x)")
    assert done == list(range(args.requests))


if __name__ == "__main__":
    main()
