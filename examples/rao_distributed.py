"""RAO killer-app demo (paper §V-A): the six CircusTent patterns on the
CXL-NIC vs PCIe-NIC models, plus the TPU-native analogue — atomic
scatter-add (Pallas kernel) and the fetch-and-add ticket sequencer.

    PYTHONPATH=src python examples/rao_distributed.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rao import RAOEngine, RAORequest
from repro.kernels import ops
from repro.simcxl.nic import RAO_PATTERNS, rao_speedups


def main():
    print("== CXL-NIC vs PCIe-NIC RAO speedups (SimCXL, Fig 17) ==")
    for pat, sp in rao_speedups(n_ops=20000).items():
        print(f"  {pat:8s} {sp:5.1f}x")

    print("== functional RAO engine (lock service counter) ==")
    eng = RAOEngine()
    for i in range(5):
        old = eng.execute(RAORequest("FAA", 0, 1))
        print(f"  ticket {old} -> counter {eng.mem[0]}")

    print("== TPU-native RAO: atomic scatter-add (Pallas kernel) ==")
    table = jnp.zeros((8, 4), jnp.float32)
    idx = jnp.asarray(np.random.RandomState(0).randint(0, 8, 128), jnp.int32)
    vals = jnp.ones((128, 4), jnp.float32)
    out = ops.rao_scatter_add(table, idx, vals)
    print(f"  row sums after 128 atomic adds: {np.asarray(out[:, 0])}")
    assert float(out.sum()) == 128 * 4


if __name__ == "__main__":
    main()
